"""T3xx — jax tracer hygiene.

Inside a traced context (a ``@jit``-decorated function, a function handed
to ``jax.jit`` / ``vmap`` / ``shard_map`` / ``pl.pallas_call`` /
``lax.while_loop``-family combinators, or any function nested in one),
values derived from the function's array arguments are tracers: Python
control flow or host synchronisation on them either raises a
``TracerBoolConversionError`` at runtime or — worse — silently bakes a
data-dependent decision into the compiled program.

* **T301** — ``if`` / ``while`` / ``for``-over / ternary / ``assert`` /
  ``bool()`` on a traced-derived value.  Use ``lax.cond`` / ``lax.select``
  / ``jnp.where`` / ``lax.while_loop`` instead.
* **T302** — host sync on a traced value: ``.item()`` / ``.tolist()`` /
  ``float()`` / ``int()`` / ``np.asarray()`` / ``np.array()``.  These force
  a device round-trip (or fail under jit) and break async dispatch.
* **T303** — a jit-decorated function closes over mutable module state
  (``global`` / ``nonlocal``, or reads a module-level name bound to a
  list/dict/set).  The first trace freezes the value; later mutations are
  silently ignored.

Taint is seeded from the traced function's parameters minus any
``static_argnames`` / ``static_argnums`` (static args are concrete), and
propagates through assignments.  Shape-metadata reads (``.shape`` /
``.ndim`` / ``.dtype`` / ``.size``), ``len()``, and identity tests
(``is`` / ``is not``) are concrete under tracing and do not taint.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..engine import Finding, ModuleContext
from . import call_name, dotted_name

RULES = {
    "T301": "Python control flow on a traced value inside a jit/shard_map/pallas body",
    "T302": "host synchronisation on a traced value inside a traced context",
    "T303": "jit-decorated function closes over mutable state",
}

# Entry points whose function-valued arguments are traced.
_TRACING_CALLS = {
    "jit", "vmap", "pmap", "shard_map", "pallas_call", "while_loop",
    "scan", "cond", "fori_loop", "switch", "grad", "value_and_grad",
    "checkpoint", "remat", "custom_vjp", "custom_jvp",
}

# Attribute reads that are concrete (not tracers) even on traced values.
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "weak_type", "sharding"}

# Builtins whose result is concrete regardless of argument taint.
_UNTAINTING_CALLS = {"len", "isinstance", "type", "id", "repr", "str", "format"}

_HOST_SYNC_METHODS = {"item", "tolist", "numpy", "block_until_ready"}
_HOST_SYNC_FUNCS = {"float", "int", "complex"}
_HOST_SYNC_NP = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}


def _leaf(name: str | None) -> str | None:
    return name.split(".")[-1] if name else None


def _jit_decorator_info(dec: ast.AST) -> tuple[bool, set[str], set[int]]:
    """(is_jit, static_argnames, static_argnums) for one decorator node."""
    static_names: set[str] = set()
    static_nums: set[int] = set()
    if isinstance(dec, ast.Call):
        callee = _leaf(dotted_name(dec.func))
        inner = None
        if callee == "partial" and dec.args:
            inner = _leaf(dotted_name(dec.args[0]))
        if callee in ("jit", "pjit") or inner in ("jit", "pjit"):
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    for sub in ast.walk(kw.value):
                        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                            static_names.add(sub.value)
                elif kw.arg == "static_argnums":
                    for sub in ast.walk(kw.value):
                        if isinstance(sub, ast.Constant) and isinstance(sub.value, int):
                            static_nums.add(sub.value)
            return True, static_names, static_nums
        return False, static_names, static_nums
    return _leaf(dotted_name(dec)) in ("jit", "pjit"), static_names, static_nums


def _collect_traced(tree: ast.Module):
    """Map function name -> (def node, static names, static nums) for traced defs."""
    defs: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)

    traced: dict[str, tuple[ast.AST, set[str], set[int]]] = {}
    jitted: set[str] = set()

    for name, fn in defs.items():
        for dec in fn.decorator_list:
            is_jit, s_names, s_nums = _jit_decorator_info(dec)
            if is_jit:
                traced[name] = (fn, s_names, s_nums)
                jitted.add(name)
            elif _leaf(dotted_name(dec)) in _TRACING_CALLS or (
                isinstance(dec, ast.Call)
                and _leaf(dotted_name(dec.func)) in _TRACING_CALLS
            ):
                traced.setdefault(name, (fn, set(), set()))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _leaf(call_name(node)) not in _TRACING_CALLS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name) and arg.id in defs:
                traced.setdefault(arg.id, (defs[arg.id], set(), set()))
                if _leaf(call_name(node)) in ("jit", "pjit"):
                    jitted.add(arg.id)
    return traced, jitted


def _mutable_module_names(tree: ast.Module) -> set[str]:
    out: set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            val = node.value
            mutable = isinstance(val, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(val, ast.Call)
                and _leaf(call_name(val)) in ("list", "dict", "set", "defaultdict",
                                              "OrderedDict", "deque")
            )
            if mutable:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out.add(tgt.id)
    return out


class _TaintChecker(ast.NodeVisitor):
    """Walk one traced function body, flagging T301/T302 on tainted values."""

    def __init__(self, ctx: ModuleContext, fn: ast.AST,
                 static_names: set[str], static_nums: set[int]):
        self.ctx = ctx
        self.findings: list[Finding] = []
        self.tainted: set[str] = set()
        args = fn.args
        ordered = [a.arg for a in args.posonlyargs + args.args]
        for i, name in enumerate(ordered):
            if name in static_names or i in static_nums or name == "self":
                continue
            self.tainted.add(name)
        for a in args.kwonlyargs:
            if a.arg not in static_names:
                self.tainted.add(a.arg)
        if args.vararg:
            self.tainted.add(args.vararg.arg)

    # -- expression taint ---------------------------------------------------

    def _tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self._tainted(node.value)
        if isinstance(node, ast.Call):
            fname = call_name(node)
            leaf = _leaf(fname)
            if leaf in _UNTAINTING_CALLS:
                return False
            if isinstance(node.func, ast.Attribute) and node.func.attr == "shape":
                return False
            return any(self._tainted(a) for a in node.args) or any(
                self._tainted(kw.value) for kw in node.keywords
            ) or self._tainted(node.func)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False  # identity tests are concrete under tracing
            return any(
                self._tainted(x) for x in [node.left, *node.comparators]
            )
        if isinstance(node, (ast.BinOp,)):
            return self._tainted(node.left) or self._tainted(node.right)
        if isinstance(node, ast.BoolOp):
            return any(self._tainted(v) for v in node.values)
        if isinstance(node, ast.UnaryOp):
            return self._tainted(node.operand)
        if isinstance(node, ast.Subscript):
            return self._tainted(node.value)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self._tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self._tainted(node.test) or self._tainted(node.body)
                    or self._tainted(node.orelse))
        if isinstance(node, ast.Starred):
            return self._tainted(node.value)
        return False

    def _taint_targets(self, target: ast.AST) -> None:
        for leaf in ast.walk(target):
            if isinstance(leaf, ast.Name):
                self.tainted.add(leaf.id)

    # -- statements ---------------------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        if self._tainted(node.value):
            for tgt in node.targets:
                self._taint_targets(tgt)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        if self._tainted(node.value):
            self._taint_targets(node.target)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        if node.value is not None and self._tainted(node.value):
            self._taint_targets(node.target)

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(
            Finding(rule, self.ctx.path, node.lineno, node.col_offset + 1, message)
        )

    def visit_If(self, node: ast.If) -> None:
        if self._tainted(node.test):
            self._flag("T301", node,
                       "`if` on a traced value — use lax.cond / jnp.where")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        if self._tainted(node.test):
            self._flag("T301", node,
                       "`while` on a traced value — use lax.while_loop")
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if self._tainted(node.iter):
            self._flag("T301", node,
                       "Python `for` over a traced value — use lax.scan / "
                       "lax.fori_loop")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        if self._tainted(node.test):
            self._flag("T301", node,
                       "ternary on a traced value — use jnp.where / lax.select")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        if self._tainted(node.test):
            self._flag("T301", node,
                       "`assert` on a traced value — use checkify or a "
                       "shape/static check")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        fname = call_name(node)
        leaf = _leaf(fname)
        args_tainted = any(self._tainted(a) for a in node.args)
        if leaf == "bool" and fname == "bool" and args_tainted:
            self._flag("T301", node,
                       "bool() on a traced value — concretisation fails under jit")
        elif fname in _HOST_SYNC_FUNCS and args_tainted:
            self._flag("T302", node,
                       f"{fname}() on a traced value forces a host sync "
                       f"(or fails under jit)")
        elif fname in _HOST_SYNC_NP and args_tainted:
            self._flag("T302", node,
                       f"{fname}() materialises a traced value on the host — "
                       f"keep the computation in jnp")
        elif (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in _HOST_SYNC_METHODS
            and self._tainted(node.func.value)
        ):
            self._flag("T302", node,
                       f".{node.func.attr}() on a traced value forces a host "
                       f"sync inside a traced context")
        self.generic_visit(node)

    # nested defs inherit the parent's taint via a fresh checker in check();
    # don't descend into them here (their params shadow scope).
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        pass

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        pass

    def visit_Lambda(self, node: ast.Lambda) -> None:
        pass


def check(ctx: ModuleContext) -> Iterator[Finding]:
    traced, jitted = _collect_traced(ctx.tree)
    mutable_globals = _mutable_module_names(ctx.tree)

    seen: set[int] = set()
    for name, (fn, s_names, s_nums) in traced.items():
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        checker = _TaintChecker(ctx, fn, s_names, s_nums)
        for stmt in fn.body:
            checker.visit(stmt)
        yield from checker.findings

        # nested defs inside a traced context are traced too: their params
        # come from the traced caller, so seed them fully tainted.
        for sub in ast.walk(fn):
            if sub is fn or not isinstance(sub, (ast.FunctionDef,
                                                 ast.AsyncFunctionDef)):
                continue
            if id(sub) in seen:
                continue
            seen.add(id(sub))
            subchecker = _TaintChecker(ctx, sub, set(), set())
            for stmt in sub.body:
                subchecker.visit(stmt)
            yield from subchecker.findings

        # T303: mutable-state closure for jit-compiled functions.
        if name in jitted:
            for sub in ast.walk(fn):
                if isinstance(sub, (ast.Global, ast.Nonlocal)):
                    yield Finding(
                        "T303", ctx.path, sub.lineno, sub.col_offset + 1,
                        f"jit-compiled {name}() mutates enclosing state "
                        f"({'global' if isinstance(sub, ast.Global) else 'nonlocal'} "
                        f"{', '.join(sub.names)}) — tracing freezes it",
                    )
                elif (
                    isinstance(sub, ast.Name)
                    and isinstance(sub.ctx, ast.Load)
                    and sub.id in mutable_globals
                ):
                    yield Finding(
                        "T303", ctx.path, sub.lineno, sub.col_offset + 1,
                        f"jit-compiled {name}() reads mutable module state "
                        f"{sub.id!r} — the first trace freezes its value; "
                        f"pass it as an argument instead",
                    )
