"""B1xx — placement-backend contract conformance.

Applies to modules that live next to a ``base.py`` inside a directory named
``placement_backends``.  The canonical method signatures are derived from
that sibling ``base.py`` itself, so the check cannot drift from the real
protocol:

* ``place_block`` comes from the :class:`PlacementBackend` Protocol body;
* ``dispatch_block`` shares ``place_block``'s signature (the async twin —
  see base.py's "Asynchronous dispatch" contract);
* ``place_blocks`` / ``dispatch_blocks`` / ``dispatch_blocks_raw`` come
  from ``dispatch_instance_blocks``'s parameter list with the leading
  ``backend`` swapped for ``self`` (the batched surface the walk feeds).

Rules:

* **B101** — a registered backend class is missing one of the five surface
  methods.  Runtime fallbacks make a missing method *silently* eager, so a
  new backend that forgets e.g. ``dispatch_blocks_raw`` loses the batched
  fast path (or worse, the ``resilience=`` plumbing a fallback happens to
  provide) without any test failing per-engine.
* **B102** — a surface method exists but its parameters don't structurally
  match base.py: names, order, kinds (keyword-only ``shard``), and default
  presence must agree.  Annotations are deliberately *not* compared.
* **B103** — registry inconsistency: the ``@register_backend("x")`` string
  must equal the class-level ``name`` attribute, and a class that looks
  like a backend (defines ``place_block``) must actually be registered.
"""

from __future__ import annotations

import ast
import os
from typing import Iterator

from ..engine import Finding, ModuleContext
from . import const_str, dotted_name

RULES = {
    "B101": "registered placement backend is missing a required surface method",
    "B102": "backend surface method signature does not match base.py",
    "B103": "backend registry registration is inconsistent",
}

SURFACE_METHODS = (
    "place_block",
    "dispatch_block",
    "place_blocks",
    "dispatch_blocks",
    "dispatch_blocks_raw",
)

# Structural signature: (positional arg names, names-with-default,
# keyword-only names, keyword-only-with-default).  Used when base.py cannot
# be parsed (and pinned by fixtures so derivation bugs surface in tests).
_FALLBACK_SPECS = {
    "place_block": (("self", "shares", "iis", "t_slr", "t_cfg", "opts"),
                    ("opts",), (), ()),
    "place_blocks": (("self", "batch", "opts"), ("opts",), ("shard",), ("shard",)),
}


def _sig_of(fn: ast.FunctionDef) -> tuple:
    args = fn.args
    pos = tuple(a.arg for a in args.posonlyargs + args.args)
    n_def = len(args.defaults)
    pos_defaulted = pos[len(pos) - n_def:] if n_def else ()
    kw = tuple(a.arg for a in args.kwonlyargs)
    kw_defaulted = tuple(
        a.arg for a, d in zip(args.kwonlyargs, args.kw_defaults, strict=True) if d is not None
    )
    return (pos, tuple(pos_defaulted), kw, kw_defaulted)


def _render_spec(spec: tuple) -> str:
    pos, pos_def, kw, kw_def = spec
    parts = [p if p not in pos_def else f"{p}=..." for p in pos]
    if kw:
        parts.append("*")
        parts.extend(k if k not in kw_def else f"{k}=..." for k in kw)
    return "(" + ", ".join(parts) + ")"


def _derive_specs(base_path: str) -> dict[str, tuple]:
    """Canonical per-method specs from the sibling base.py (cached)."""
    try:
        with open(base_path, encoding="utf-8") as fh:
            tree = ast.parse(fh.read(), filename=base_path)
    except (OSError, SyntaxError):
        tree = None
    specs = dict(_FALLBACK_SPECS)
    if tree is not None:
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == "PlacementBackend":
                for item in node.body:
                    if isinstance(item, ast.FunctionDef) and item.name == "place_block":
                        specs["place_block"] = _sig_of(item)
            if isinstance(node, ast.FunctionDef) and (
                node.name == "dispatch_instance_blocks"
            ):
                pos, pos_def, kw, kw_def = _sig_of(node)
                # swap the free function's leading `backend` for `self`
                specs["place_blocks"] = (("self",) + pos[1:], pos_def, kw, kw_def)
    specs["dispatch_block"] = specs["place_block"]
    specs["dispatch_blocks"] = specs["place_blocks"]
    specs["dispatch_blocks_raw"] = specs["place_blocks"]
    return specs


_SPEC_CACHE: dict[str, dict[str, tuple]] = {}


def _registered_name(cls: ast.ClassDef) -> tuple[str | None, ast.AST | None]:
    """The ``@register_backend("x")`` string, if any, and the decorator node."""
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call):
            callee = dotted_name(dec.func)
            if callee and callee.split(".")[-1] == "register_backend" and dec.args:
                return const_str(dec.args[0]), dec
    return None, None


def _name_attr(cls: ast.ClassDef) -> str | None:
    for item in cls.body:
        if isinstance(item, ast.Assign):
            for tgt in item.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "name":
                    return const_str(item.value)
        elif isinstance(item, ast.AnnAssign):
            if (
                isinstance(item.target, ast.Name)
                and item.target.id == "name"
                and item.value is not None
            ):
                return const_str(item.value)
    return None


def _methods(cls: ast.ClassDef) -> dict[str, ast.FunctionDef]:
    return {
        item.name: item
        for item in cls.body
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


def check(ctx: ModuleContext) -> Iterator[Finding]:
    dirname, fname = os.path.split(ctx.abspath)
    if os.path.basename(dirname) != "placement_backends":
        return
    if fname in ("base.py", "__init__.py"):
        return
    base_path = os.path.join(dirname, "base.py")
    if not os.path.exists(base_path):
        return
    if base_path not in _SPEC_CACHE:
        _SPEC_CACHE[base_path] = _derive_specs(base_path)
    specs = _SPEC_CACHE[base_path]

    for node in ctx.tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        reg_name, reg_node = _registered_name(node)
        methods = _methods(node)
        if reg_node is None:
            if "place_block" in methods:
                yield Finding(
                    "B103", ctx.path, node.lineno, node.col_offset + 1,
                    f"class {node.name!r} defines place_block but is never "
                    f"registered with @register_backend(...)",
                )
            continue
        name_attr = _name_attr(node)
        if reg_name is None:
            yield Finding(
                "B103", ctx.path, reg_node.lineno, reg_node.col_offset + 1,
                f"@register_backend on {node.name!r} must be called with a "
                f"string literal engine name",
            )
        elif name_attr != reg_name:
            yield Finding(
                "B103", ctx.path, node.lineno, node.col_offset + 1,
                f"class {node.name!r} registered as {reg_name!r} but its "
                f"`name` attribute is {name_attr!r} — registry lookups and "
                f"error messages must agree",
            )
        for meth in SURFACE_METHODS:
            fn = methods.get(meth)
            if fn is None:
                yield Finding(
                    "B101", ctx.path, node.lineno, node.col_offset + 1,
                    f"backend {node.name!r} is missing {meth}{_render_spec(specs[meth])} "
                    f"— the full surface is required so fallback paths (and "
                    f"resilience= plumbing) are explicit, not accidental",
                )
                continue
            got = _sig_of(fn)
            if got != specs[meth]:
                yield Finding(
                    "B102", ctx.path, fn.lineno, fn.col_offset + 1,
                    f"{node.name}.{meth} signature {_render_spec(got)} does not "
                    f"structurally match base.py's {_render_spec(specs[meth])}",
                )


def _reset_cache() -> None:
    """Test hook: drop memoized base.py specs."""
    _SPEC_CACHE.clear()
