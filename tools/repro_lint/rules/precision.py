"""P2xx — float-precision flow.

The paper's scheduling guarantee is an *exactness* claim: every backend
replays the same float64 operations in the same order, power ties break on
bit-equal totals, and ``resilience=`` survivor tables are selected at
float64 before any f32 cast (the pallas TPU lowering).  These rules reject
the precision mistakes that silently flip verdicts near eq-7 boundaries:

* **P201** — ``==`` / ``!=`` where an operand is float-valued (a float
  literal, float arithmetic, or a ``float()``/``np.float32()``-style call).
  Exact float equality is only sound when both sides are bit-identical by
  construction (the power-tie contract); such intentional sites must carry
  a suppression explaining why exactness holds.
* **P202** — a value derived from a float32 cast (``.astype(np.float32)``,
  ``jnp.float32(x)``, ``lax.convert_element_type(x, f32)``) flows into an
  ordering comparison or into survivor-table selection
  (``worst_case_survivor_indices`` / ``survivor_tables`` /
  ``argsort``/``argmin``/…).  Thresholds and survivor adversaries must be
  decided at float64; casting first reorders near-tie verdicts.
* **P203** — implicit or explicit narrowing in precision-critical modules
  (path contains ``/core/`` or the placement kernel files, or the module
  carries a ``# repro-lint: precision-critical`` pragma):
  ``jnp.asarray``/``jnp.array`` without an explicit ``dtype=`` (silently
  float32 under default jax config), or array constructors with an explicit
  float32 dtype.  Analysis taint is intraprocedural and assignment-based.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator

from ..engine import Finding, ModuleContext
from . import call_name, dotted_name, is_float32_dtype

RULES = {
    "P201": "float equality comparison (== / != on float-valued operands)",
    "P202": "float32-cast value reaches a threshold comparison or survivor selection",
    "P203": "dtype narrowing in a precision-critical module",
}

_PRECISION_PATH_RE = re.compile(
    r"(/|^)core(/|$)|kernels/(placement_step|ref|ops)\.py$"
)

_FLOAT_CALLS = {"float", "float32", "float64", "fsum"}
_SELECTION_CALLS = {
    "worst_case_survivor_indices",
    "survivor_tables",
    "survivor_batch_tables",
    "argsort",
    "lexsort",
    "argmin",
    "argmax",
    "searchsorted",
}
_ARRAY_CTORS = {
    "zeros", "ones", "empty", "full", "asarray", "array",
    "zeros_like", "ones_like", "empty_like", "full_like", "arange", "linspace",
}


def _is_floaty(node: ast.AST) -> bool:
    """Is this expression float-valued on its face (literal / arithmetic)?"""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, float)
    if isinstance(node, ast.UnaryOp):
        return _is_floaty(node.operand)
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, ast.Div):  # true division is float-valued
            return True
        return _is_floaty(node.left) or _is_floaty(node.right)
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name is not None and name.split(".")[-1] in _FLOAT_CALLS:
            return True
    return False


def _is_f32_cast(node: ast.AST) -> bool:
    """``x.astype(float32-ish)``, ``np/jnp.float32(x)``, convert_element_type."""
    if not isinstance(node, ast.Call):
        return False
    if isinstance(node.func, ast.Attribute) and node.func.attr == "astype":
        return bool(node.args) and is_float32_dtype(node.args[0]) or any(
            kw.arg == "dtype" and is_float32_dtype(kw.value)
            for kw in node.keywords
        )
    name = call_name(node)
    if name is None:
        return False
    leaf = name.split(".")[-1]
    if leaf == "float32" and node.args:
        return True
    if leaf == "convert_element_type":
        dtype_args = list(node.args[1:]) + [
            kw.value for kw in node.keywords if kw.arg in ("new_dtype", "dtype")
        ]
        return any(is_float32_dtype(a) for a in dtype_args)
    return False


def _check_p201(ctx: ModuleContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Compare):
            continue
        operands = [node.left, *node.comparators]
        for i, op in enumerate(node.ops):
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            left, right = operands[i], operands[i + 1]
            if _is_floaty(left) or _is_floaty(right):
                yield Finding(
                    "P201", ctx.path, node.lineno, node.col_offset + 1,
                    "float equality comparison — use an integer/exact "
                    "representation, a tolerance, or suppress with the "
                    "bit-exactness argument written down",
                )
                break  # one finding per compare chain


class _F32Flow(ast.NodeVisitor):
    """Intra-function taint: names assigned from f32 casts -> comparisons/selection."""

    def __init__(self, ctx: ModuleContext):
        self.ctx = ctx
        self.findings: list[Finding] = []
        self.tainted: set[str] = set()

    def _expr_tainted(self, node: ast.AST) -> bool:
        if _is_f32_cast(node):
            return True
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and sub.id in self.tainted:
                return True
            if sub is not node and _is_f32_cast(sub):
                return True
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        if self._expr_tainted(node.value):
            for tgt in node.targets:
                for leaf in ast.walk(tgt):
                    if isinstance(leaf, ast.Name):
                        self.tainted.add(leaf.id)
        self.generic_visit(node)

    # Nested defs get their own _F32Flow pass (taint does not cross scopes);
    # not descending here keeps findings single-reported.
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        pass

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        pass

    def visit_Lambda(self, node: ast.Lambda) -> None:
        pass

    def visit_Compare(self, node: ast.Compare) -> None:
        if all(
            isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
            for op in node.ops
        ):
            # identity/membership tests are not float thresholds
            self.generic_visit(node)
            return
        for operand in [node.left, *node.comparators]:
            if self._expr_tainted(operand):
                self.findings.append(
                    Finding(
                        "P202", self.ctx.path, node.lineno, node.col_offset + 1,
                        "float32-cast value reaches a comparison — eq-7-style "
                        "thresholds must be evaluated at float64",
                    )
                )
                break
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        name = call_name(node)
        leaf = name.split(".")[-1] if name else None
        if leaf in _SELECTION_CALLS:
            if any(self._expr_tainted(a) for a in node.args) or any(
                self._expr_tainted(kw.value) for kw in node.keywords
            ):
                self.findings.append(
                    Finding(
                        "P202", self.ctx.path, node.lineno, node.col_offset + 1,
                        f"float32-cast value feeds {leaf}() — survivor tables "
                        f"and orderings must be selected at float64, before "
                        f"any f32 cast",
                    )
                )
        self.generic_visit(node)


def _precision_scope(ctx: ModuleContext) -> bool:
    return ctx.precision_critical or bool(_PRECISION_PATH_RE.search(ctx.path))


def _check_p202(ctx: ModuleContext) -> Iterator[Finding]:
    # The f32-flow contract is about the scheduling chain (eq-7 thresholds,
    # survivor selection); ML model code routinely routes at f32 by design.
    if not _precision_scope(ctx):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            flow = _F32Flow(ctx)
            for stmt in node.body:
                flow.visit(stmt)
            yield from flow.findings


def _has_dtype(node: ast.Call, n_positional_before_dtype: int = 1) -> bool:
    if len(node.args) > n_positional_before_dtype:
        return True
    return any(kw.arg == "dtype" for kw in node.keywords)


def _check_p203(ctx: ModuleContext) -> Iterator[Finding]:
    if not _precision_scope(ctx):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name is None:
            continue
        root, _, rest = name.partition(".")
        leaf = name.split(".")[-1]
        if root in ("jnp", "jax") and leaf in ("asarray", "array"):
            if not _has_dtype(node):
                yield Finding(
                    "P203", ctx.path, node.lineno, node.col_offset + 1,
                    f"{name}(...) without an explicit dtype narrows float64 "
                    f"to float32 under default jax config — pass dtype=",
                )
        elif leaf in _ARRAY_CTORS:
            dtype_args = [kw.value for kw in node.keywords if kw.arg == "dtype"]
            if leaf in _ARRAY_CTORS and len(node.args) > 1:
                dtype_args.append(node.args[1])
            if any(is_float32_dtype(a) for a in dtype_args):
                yield Finding(
                    "P203", ctx.path, node.lineno, node.col_offset + 1,
                    f"float32 allocation ({name}) in a precision-critical "
                    f"module — the placement chain is float64; cast at the "
                    f"kernel boundary only",
                )


def check(ctx: ModuleContext) -> Iterator[Finding]:
    yield from _check_p201(ctx)
    yield from _check_p202(ctx)
    yield from _check_p203(ctx)
