"""Rule families for repro-lint.

Each submodule exposes ``RULES`` (id -> summary) and ``check(ctx)``.  This
package also hosts the small AST helpers shared by the families.
"""

from __future__ import annotations

import ast

__all__ = ["dotted_name", "call_name", "const_str", "is_float32_dtype"]


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    """Dotted name of a call's callee (``np.random.rand`` -> that string)."""
    return dotted_name(node.func)


def const_str(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def is_float32_dtype(node: ast.AST) -> bool:
    """Does this expression denote a float32 dtype (np/jnp attr or string)?"""
    name = dotted_name(node)
    if name is not None and name.split(".")[-1] == "float32":
        return True
    s = const_str(node)
    return s in ("float32", "f32", "<f4", "float32_t")
