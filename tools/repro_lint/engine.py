"""repro-lint engine: file walking, suppressions, rule dispatch, reporting.

The engine is rule-agnostic: each rule family lives in
``tools.repro_lint.rules.<family>`` and exposes

* ``RULES: dict[str, str]`` — rule id -> one-line summary (the catalog), and
* ``check(ctx: ModuleContext) -> Iterable[Finding]``.

The engine parses each file once into a :class:`ModuleContext`, runs every
family, then applies per-line suppressions of the form::

    <code>  # repro-lint: ignore[P201]  # why this is intentionally exact

Multiple ids may be listed (``ignore[P201,D401]``).  The trailing reason is
mandatory: a reasonless suppression becomes an ``S001`` finding (which is
itself unsuppressable — fix it by writing the reason down).  Suppressions
match a finding by (line, rule id); for multi-line statements the relevant
line is the statement's *first* line (``node.lineno``).

A module may opt into the precision-critical rule scope (normally keyed off
the file path) with a ``# repro-lint: precision-critical`` pragma anywhere
in the file — see :mod:`tools.repro_lint.rules.precision`.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Iterable, Iterator

__all__ = [
    "Finding",
    "LintResult",
    "ModuleContext",
    "Suppression",
    "all_rules",
    "collect_files",
    "lint_source",
    "run_paths",
    "to_json",
]

# Engine-level rules (rule families document theirs in rules/*.py).
ENGINE_RULES = {
    "E001": "file does not parse (syntax error)",
    "S001": "repro-lint suppression without a written reason",
}

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*ignore\[([A-Za-z0-9_,\s]+)\](.*)$"
)
_PRECISION_PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*precision-critical\b")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    """A parsed ``# repro-lint: ignore[...]`` comment."""

    path: str
    line: int
    rules: tuple[str, ...]
    reason: str


@dataclasses.dataclass
class ModuleContext:
    """Everything a rule checker needs about one parsed module."""

    path: str  # normalized, '/'-separated display path
    abspath: str
    source: str
    tree: ast.Module
    lines: list[str]
    precision_critical: bool = False  # module-level pragma (see precision rules)

    @classmethod
    def from_source(cls, source: str, path: str, abspath: str | None = None):
        tree = ast.parse(source, filename=path)
        return cls(
            path=path.replace(os.sep, "/"),
            abspath=abspath or path,
            source=source,
            tree=tree,
            lines=source.splitlines(),
            precision_critical=bool(_PRECISION_PRAGMA_RE.search(source)),
        )


@dataclasses.dataclass
class LintResult:
    """Aggregate outcome of one lint run."""

    findings: list[Finding]
    suppressed: list[tuple[Finding, str]]  # (finding, reason)
    files: list[str]

    @property
    def ok(self) -> bool:
        return not self.findings


def _rule_modules():
    # Imported lazily so `import tools.repro_lint` stays cheap and the rules
    # package can import the engine's types without a cycle.
    from .rules import backend_contract, determinism, precision, tracer

    return (backend_contract, precision, tracer, determinism)


def all_rules() -> dict[str, str]:
    """The full rule catalog: id -> one-line summary (stable, documented)."""
    catalog = dict(ENGINE_RULES)
    for mod in _rule_modules():
        catalog.update(mod.RULES)
    return catalog


def parse_suppressions(ctx: ModuleContext) -> tuple[list[Suppression], list[Finding]]:
    """Extract suppression comments; reasonless ones become S001 findings."""
    sups: list[Suppression] = []
    findings: list[Finding] = []
    for lineno, line in enumerate(ctx.lines, 1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        reason = m.group(2).strip().lstrip("#").strip()
        if not reason:
            findings.append(
                Finding(
                    rule="S001",
                    path=ctx.path,
                    line=lineno,
                    col=m.start() + 1,
                    message=(
                        "suppression needs a written reason: "
                        "`# repro-lint: ignore[RULE]  # why`"
                    ),
                )
            )
            continue
        sups.append(Suppression(path=ctx.path, line=lineno, rules=rules, reason=reason))
    return sups, findings


def lint_module(ctx: ModuleContext) -> tuple[list[Finding], list[tuple[Finding, str]]]:
    """Run every rule family over one module and apply suppressions."""
    raw: list[Finding] = []
    for mod in _rule_modules():
        raw.extend(mod.check(ctx))
    sups, findings = parse_suppressions(ctx)
    by_line: dict[int, list[Suppression]] = {}
    for s in sups:
        by_line.setdefault(s.line, []).append(s)
    suppressed: list[tuple[Finding, str]] = []
    for f in raw:
        hit = next(
            (s for s in by_line.get(f.line, ()) if f.rule in s.rules),
            None,
        )
        if hit is not None:
            suppressed.append((f, hit.reason))
        else:
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, suppressed


def lint_source(
    source: str, path: str = "<snippet>", select: Iterable[str] | None = None
) -> list[Finding]:
    """Lint a source string (used by ``tools/check_docs.py`` on doc snippets).

    Returns post-suppression findings only; a syntax error yields a single
    ``E001`` finding rather than raising.
    """
    try:
        ctx = ModuleContext.from_source(source, path)
    except SyntaxError as e:
        return [
            Finding(
                rule="E001",
                path=path,
                line=e.lineno or 1,
                col=e.offset or 1,
                message=f"syntax error: {e.msg}",
            )
        ]
    findings, _ = lint_module(ctx)
    return _select(findings, select)


def _select(findings: list[Finding], select: Iterable[str] | None) -> list[Finding]:
    if select is None:
        return findings
    wanted = tuple(select)
    return [f for f in findings if any(f.rule.startswith(w) for w in wanted)]


def collect_files(paths: Iterable[str], root: str | None = None) -> Iterator[str]:
    """Yield ``.py`` files under ``paths`` (files pass through), sorted.

    Hidden directories and ``__pycache__`` are skipped; traversal order is
    sorted so runs are byte-stable across filesystems.
    """
    root = root or os.getcwd()
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            yield full
            continue
        for dirpath, dirnames, filenames in sorted(os.walk(full)):
            dirnames[:] = sorted(
                d for d in dirnames if not d.startswith(".") and d != "__pycache__"
            )
            for name in sorted(filenames):
                if name.endswith(".py"):
                    yield os.path.join(dirpath, name)


def run_paths(
    paths: Iterable[str],
    root: str | None = None,
    select: Iterable[str] | None = None,
) -> LintResult:
    """Lint every ``.py`` file under ``paths``; paths reported relative to ``root``."""
    root = os.path.abspath(root or os.getcwd())
    findings: list[Finding] = []
    suppressed: list[tuple[Finding, str]] = []
    files: list[str] = []
    for abspath in collect_files(paths, root):
        rel = os.path.relpath(abspath, root).replace(os.sep, "/")
        files.append(rel)
        try:
            with open(abspath, encoding="utf-8") as fh:
                source = fh.read()
        except OSError as e:
            findings.append(
                Finding("E001", rel, 1, 1, f"cannot read file: {e}")
            )
            continue
        try:
            ctx = ModuleContext.from_source(source, rel, abspath)
        except SyntaxError as e:
            findings.append(
                Finding(
                    "E001", rel, e.lineno or 1, e.offset or 1,
                    f"syntax error: {e.msg}",
                )
            )
            continue
        f, s = lint_module(ctx)
        findings.extend(f)
        suppressed.extend(s)
    return LintResult(
        findings=_select(findings, select), suppressed=suppressed, files=files
    )


def to_json(result: LintResult) -> str:
    """Machine-readable report (schema pinned by ``tests/test_repro_lint.py``)."""
    payload = {
        "version": 1,
        "rules": all_rules(),
        "files": result.files,
        "findings": [dataclasses.asdict(f) for f in result.findings],
        "suppressed": [
            {**dataclasses.asdict(f), "reason": reason}
            for f, reason in result.suppressed
        ],
        "counts": {
            "findings": len(result.findings),
            "suppressed": len(result.suppressed),
            "files": len(result.files),
        },
    }
    return json.dumps(payload, indent=2, sort_keys=True)
